"""Shape/policy sweep: cache_sim Pallas kernel (interpret) vs pure-jnp oracle.

Every registry kind — the sketch-admission ones included — runs on the kernel
tier; the sweep pins parity on hits, final cache contents and the frequency
table against ``jax_cache.simulate`` (itself oracle-validated against the
pure-Python references in tests/test_differential.py).
"""
import numpy as np
import pytest

from repro.core import jax_cache, registry, zipf
from repro.kernels.cache_sim.ops import cache_sim
from repro.kernels.cache_sim.ref import cache_sim_ref

SWEEP = [
    # (kind, n_objects, capacity, n_samples, trace_len, kwargs)
    ("lfu", 64, 9, 3, 400, {}),
    ("lfu", 200, 50, 2, 600, {}),
    ("plfu", 64, 9, 3, 400, {}),
    ("plfu", 130, 3, 2, 500, {}),
    ("plfua", 64, 9, 3, 400, {}),
    ("plfua", 300, 20, 2, 500, {}),
    ("lru", 64, 9, 3, 400, {}),
    ("lru", 100, 25, 2, 500, {}),
    ("lfu", 128, 128, 2, 300, {}),   # capacity == N: never evicts
    ("plfu", 16, 1, 2, 300, {}),     # degenerate single-slot cache
    ("wlfu", 64, 9, 3, 400, dict(window=48)),
    ("wlfu", 130, 3, 2, 500, dict(window=33)),  # odd window, n crosses a pad
    ("tinylfu", 64, 9, 3, 400, dict(window=48, sketch_width=64)),
    ("tinylfu", 300, 20, 2, 500, dict(window=77, sketch_width=100)),
    ("tinylfu", 64, 9, 2, 400, {}),  # defaults: window=1000 > T, no aging
    ("plfua_dyn", 64, 9, 3, 400, dict(refresh=97, sketch_width=64)),
    ("plfua_dyn", 130, 3, 2, 500, dict(refresh=50, sketch_width=96, hot_size=7)),
    ("plfua_dyn", 16, 1, 2, 300, dict(refresh=30, sketch_width=64)),
]


def _assert_matches_oracle(kind, n, cap, traces, **kw):
    hits_k, freq_k, cache_k = cache_sim(
        traces, kind=kind, n_objects=n, capacity=cap, interpret=True, **kw
    )
    hits_r, freq_r, cache_r = cache_sim_ref(
        traces, kind=kind, n_objects=n, capacity=cap, **kw
    )
    np.testing.assert_array_equal(np.asarray(hits_k), hits_r)
    np.testing.assert_array_equal(np.asarray(cache_k), cache_r)
    if kind == "lru":
        # stamps meaningful only for cached entries (oracle lacks eviction wipes)
        np.testing.assert_array_equal(
            np.asarray(freq_k)[cache_r], freq_r[cache_r]
        )
    else:
        np.testing.assert_array_equal(np.asarray(freq_k), freq_r)


@pytest.mark.parametrize("kind,n,cap,s,t,kw", SWEEP)
def test_kernel_matches_oracle(kind, n, cap, s, t, kw):
    traces = np.stack(
        [zipf.sample_trace(n, t, seed=100 + i) for i in range(s)]
    ).astype(np.int32)
    _assert_matches_oracle(kind, n, cap, traces, **kw)


def test_kernel_uniform_trace_dtype_robustness():
    rng = np.random.default_rng(0)
    traces = rng.integers(0, 77, size=(2, 321)).astype(np.int32)
    for kind in ("lfu", "plfu", "plfua", "lru"):
        hits_k, _, cache_k = cache_sim(
            traces, kind=kind, n_objects=77, capacity=13, interpret=True
        )
        hits_r, _, cache_r = cache_sim_ref(
            traces, kind=kind, n_objects=77, capacity=13
        )
        np.testing.assert_array_equal(np.asarray(hits_k), hits_r)
        np.testing.assert_array_equal(np.asarray(cache_k), cache_r)


def test_kernel_implements_every_registry_kind():
    """The NotImplementedError gate is gone: the registry advertises Pallas
    support for all kinds, and KERNEL_KINDS covers the whole canonical list."""
    from repro.kernels.cache_sim.ops import KERNEL_KINDS

    assert KERNEL_KINDS == registry.names()
    assert set(jax_cache.SKETCH_POLICY_KINDS) <= set(KERNEL_KINDS)
    for p in registry.POLICIES:
        assert p.pallas, f"{p.name} lost kernel support"


def test_kernel_rejects_unknown_kind_and_bad_options():
    traces = np.zeros((1, 16), np.int32)
    with pytest.raises(ValueError, match="not in"):
        cache_sim(traces, kind="nope", n_objects=32, capacity=4, interpret=True)
    with pytest.raises(ValueError, match="window"):
        cache_sim(traces, kind="wlfu", n_objects=32, capacity=4, interpret=True)
    with pytest.raises(ValueError, match="doorkeeper"):
        cache_sim(
            traces, kind="lfu", n_objects=32, capacity=4, doorkeeper=64,
            interpret=True,
        )


def test_kernel_tinylfu_doorkeeper_matches_oracle():
    """The bloom front changes admission decisions (first touch per window is
    doorkeeper'd) — the kernel must track the jnp tier through them."""
    n, cap, t = 64, 9, 500
    traces = np.stack([zipf.sample_trace(n, t, seed=5 + i) for i in range(2)])
    kw = dict(window=60, sketch_width=64, doorkeeper=128)
    _assert_matches_oracle("tinylfu", n, cap, traces.astype(np.int32), **kw)
    # ...and the doorkeeper'd run really made different decisions
    hits_dk, _, _ = cache_sim(
        traces, kind="tinylfu", n_objects=n, capacity=cap, interpret=True, **kw
    )
    hits_plain, _, _ = cache_sim(
        traces, kind="tinylfu", n_objects=n, capacity=cap, interpret=True,
        window=60, sketch_width=64,
    )
    assert not np.array_equal(np.asarray(hits_dk), np.asarray(hits_plain))


@pytest.mark.parametrize("trace_len", [388, 400])  # 388 = 4*97: exact periods
def test_kernel_plfua_dyn_refresh_boundary(trace_len):
    """Global-time refresh cadence: a partial tail period must NOT fire a
    refresh (trace_len % refresh != 0), and an exact multiple must fire on
    the last step — both bit-identical to the chunked jnp scan."""
    n, cap, refresh = 64, 9, 97
    traces = np.stack(
        [zipf.sample_trace(n, trace_len, seed=40 + i) for i in range(2)]
    ).astype(np.int32)
    _assert_matches_oracle(
        "plfua_dyn", n, cap, traces, refresh=refresh, sketch_width=64
    )


def test_kernel_plfua_custom_hot_size():
    traces = np.stack([zipf.sample_trace(50, 400, seed=7)])
    hits_k, _, cache_k = cache_sim(
        traces, kind="plfua", n_objects=50, capacity=5, hot_size=7, interpret=True
    )
    hits_r, _, cache_r = cache_sim_ref(
        traces, kind="plfua", n_objects=50, capacity=5, hot_size=7
    )
    np.testing.assert_array_equal(np.asarray(hits_k), hits_r)
    np.testing.assert_array_equal(np.asarray(cache_k), cache_r)
    assert not np.asarray(cache_k)[:, 7:].any()  # cold ids never admitted
