"""Shape/policy sweep: cache_sim Pallas kernel (interpret) vs pure-jnp oracle."""
import numpy as np
import pytest

from repro.core import jax_cache, zipf
from repro.kernels.cache_sim.ops import cache_sim
from repro.kernels.cache_sim.ref import cache_sim_ref

SWEEP = [
    # (kind, n_objects, capacity, n_samples, trace_len)
    ("lfu", 64, 9, 3, 400),
    ("lfu", 200, 50, 2, 600),
    ("plfu", 64, 9, 3, 400),
    ("plfu", 130, 3, 2, 500),
    ("plfua", 64, 9, 3, 400),
    ("plfua", 300, 20, 2, 500),
    ("lru", 64, 9, 3, 400),
    ("lru", 100, 25, 2, 500),
    ("lfu", 128, 128, 2, 300),   # capacity == N: never evicts
    ("plfu", 16, 1, 2, 300),     # degenerate single-slot cache
]


@pytest.mark.parametrize("kind,n,cap,s,t", SWEEP)
def test_kernel_matches_oracle(kind, n, cap, s, t):
    traces = np.stack(
        [zipf.sample_trace(n, t, seed=100 + i) for i in range(s)]
    ).astype(np.int32)
    hits_k, freq_k, cache_k = cache_sim(
        traces, kind=kind, n_objects=n, capacity=cap, interpret=True
    )
    hits_r, freq_r, cache_r = cache_sim_ref(
        traces, kind=kind, n_objects=n, capacity=cap
    )
    np.testing.assert_array_equal(np.asarray(hits_k), hits_r)
    np.testing.assert_array_equal(np.asarray(cache_k), cache_r)
    if kind == "lru":
        # stamps meaningful only for cached entries (oracle lacks eviction wipes)
        np.testing.assert_array_equal(
            np.asarray(freq_k)[cache_r], freq_r[cache_r]
        )
    else:
        np.testing.assert_array_equal(np.asarray(freq_k), freq_r)


def test_kernel_uniform_trace_dtype_robustness():
    rng = np.random.default_rng(0)
    traces = rng.integers(0, 77, size=(2, 321)).astype(np.int32)
    for kind in ("lfu", "plfu", "plfua", "lru"):
        hits_k, _, cache_k = cache_sim(
            traces, kind=kind, n_objects=77, capacity=13, interpret=True
        )
        hits_r, _, cache_r = cache_sim_ref(
            traces, kind=kind, n_objects=77, capacity=13
        )
        np.testing.assert_array_equal(np.asarray(hits_k), hits_r)
        np.testing.assert_array_equal(np.asarray(cache_k), cache_r)


@pytest.mark.parametrize("kind", jax_cache.SKETCH_POLICY_KINDS)
def test_kernel_sketch_kinds_raise_loudly(kind):
    """The kernel doesn't implement sketch admission; it must say so with a
    typed error, never fall through to a silently-wrong simulation."""
    traces = np.zeros((1, 16), np.int32)
    with pytest.raises(NotImplementedError, match="sketch-admission"):
        cache_sim(traces, kind=kind, n_objects=32, capacity=4, interpret=True)
    # ...while the jitted jnp tier does support them on identical inputs
    spec = jax_cache.PolicySpec(kind=kind, n_objects=32, capacity=4)
    hits, _ = jax_cache.simulate(spec, traces[0])
    assert np.asarray(hits).shape == (16,)


def test_kernel_plfua_custom_hot_size():
    traces = np.stack([zipf.sample_trace(50, 400, seed=7)])
    hits_k, _, cache_k = cache_sim(
        traces, kind="plfua", n_objects=50, capacity=5, hot_size=7, interpret=True
    )
    hits_r, _, cache_r = cache_sim_ref(
        traces, kind="plfua", n_objects=50, capacity=5, hot_size=7
    )
    np.testing.assert_array_equal(np.asarray(hits_k), hits_r)
    np.testing.assert_array_equal(np.asarray(cache_k), cache_r)
    assert not np.asarray(cache_k)[:, 7:].any()  # cold ids never admitted
