"""Stream ↔ bounded differential suite (PR 10).

The streaming fleet engine (:mod:`repro.fleet.stream`) promises *bit
identity*: K pushed chunks of length G reproduce ``simulate_fleet`` (or the
flat ``jax_cache.simulate``) on the concatenated trace exactly — hit series,
final states, tier counters, grouped telemetry series stitched across chunk
boundaries, eviction pressure. That promise is what makes the line-rate
bench numbers (BENCH_PR10 ``fleet_stream`` group) legitimate measurements of
*the same algorithms* the paper tables score, so this suite pins it over:

* all 9 policy kinds × stationary/churn on a depth-2 tree with grouped
  telemetry (level-major engine underneath);
* the placed engine (lcd / prob / admit) on a plfua_dyn tree, where the
  stream's traced global-time fire schedule must reproduce the bounded
  host-side one — including a chunk length that does *not* divide the
  refresh period (gcd sub-chunking);
* the fast compact-lane path against the dense flat simulator for every
  FAST_KIND (the candidate-prefix bound, tie-breaks included);
* the double-buffered ``stream_fleet`` driver against a bounded run over
  the same on-device-generated chunks.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro import fleet, workloads
from repro.core import jax_cache
from repro.core.jax_cache import PolicySpec
from repro.fleet.stream import FAST_KINDS, FleetStream, StreamConfig, stream_fleet
from repro.telemetry import TelemetrySpec

N, G, K = 96, 50, 4
T = G * K
ALL_KINDS = ("lru", "lfu", "wlfu", "plfu", "plfua", "plfua_dyn", "tinylfu", "gdsf", "arc")

_rng = np.random.default_rng(0)
GROUPS = _rng.integers(0, 3, size=N).astype(np.int32)
SIZES = _rng.integers(1, 9, size=N).astype(np.int32)
TEL = TelemetrySpec(window=25, n_groups=3)


def _topo(kind, **kw):
    return fleet.tree(
        n_objects=N, widths=(3, 1), kinds=kind, capacities=(5, 13),
        window=48 if kind == "wlfu" else 0,
        refresh=30 if kind == "plfua_dyn" else 0,
        **kw,
    )


def _run_stream(cfg, trace, assignment, **kw):
    """Push the trace through in K chunks; return (FleetStream, per-chunk hit
    tuples)."""
    fs = FleetStream(cfg, **kw)
    hits = []
    for c in range(K):
        sl = slice(c * G, (c + 1) * G)
        a = None if assignment is None else jnp.asarray(assignment[sl])
        out = fs.push(jnp.asarray(trace[sl]), a)
        hits.append(out["hit"])
    return fs, hits


def _assert_stream_matches(bounded, fs, hits_chunks, *, tel=False, ctx=""):
    """Full bounded-vs-stream parity: hit series, counters, states, rollup,
    and (with ``tel``) the stitched telemetry series + pressure."""
    st = fs.stats()
    for l in range(len(bounded["hit"])):
        cat = np.concatenate([np.asarray(h[l]) for h in hits_chunks])
        np.testing.assert_array_equal(
            cat, np.asarray(bounded["hit"][l]), err_msg=f"{ctx}: hit level {l}"
        )
        for k in bounded["tiers"][l]:
            np.testing.assert_array_equal(
                np.asarray(bounded["tiers"][l][k]), np.asarray(st.tiers[l][k]),
                err_msg=f"{ctx}: tiers[{l}][{k}]",
            )
        for k in bounded["states"][l]:
            np.testing.assert_array_equal(
                np.asarray(bounded["states"][l][k]),
                np.asarray(fs.states()[l][k]),
                err_msg=f"{ctx}: states[{l}][{k}]",
            )
    assert st.requests == T and st.chunks == K
    assert st.origin_misses == int(np.asarray(bounded["origin_miss"]).sum()), ctx
    assert st.hits == T - st.origin_misses
    assert st.total_chr == pytest.approx(st.hits / T)
    if tel:
        for l in range(len(bounded["telemetry"])):
            np.testing.assert_array_equal(
                np.asarray(bounded["telemetry"][l]), np.asarray(st.telemetry[l]),
                err_msg=f"{ctx}: telemetry level {l}",
            )
        for l in range(len(bounded["telemetry_pressure"])):
            np.testing.assert_array_equal(
                np.asarray(bounded["telemetry_pressure"][l]),
                np.asarray(st.telemetry_pressure[l]),
                err_msg=f"{ctx}: pressure level {l}",
            )


# ----------------------------------------------------------- config contract
def test_stream_config_validation():
    topo = _topo("lru")
    with pytest.raises(ValueError, match="chunk_len"):
        StreamConfig(topo=topo, chunk_len=0)
    # position-keyed upper routers would diverge when the stream resets t
    sticky = fleet.tree(
        n_objects=N, widths=(3, 2, 1), kinds="lru", capacities=(5, 9, 13),
        routers=("hash", "sticky", "tree"),
    )
    with pytest.raises(ValueError, match="position-independent"):
        StreamConfig(topo=sticky, chunk_len=G)
    # telemetry windows must tile the chunk so series stitch by concatenation
    with pytest.raises(ValueError, match="window"):
        StreamConfig(topo=topo, chunk_len=G, telemetry=TelemetrySpec(window=30))
    # fast-path preconditions
    with pytest.raises(ValueError, match="depth-1"):
        StreamConfig(topo=topo, chunk_len=G, fast=True)
    flat_arc = fleet.tree(n_objects=N, widths=(1,), kinds="arc", capacities=13)
    with pytest.raises(ValueError, match="fast=True supports"):
        StreamConfig(topo=flat_arc, chunk_len=G, fast=True)
    flat = fleet.tree(n_objects=N, widths=(1,), kinds="lru", capacities=13)
    with pytest.raises(ValueError, match="telemetry"):
        StreamConfig(
            topo=flat, chunk_len=G, fast=True, telemetry=TelemetrySpec(window=25)
        )
    dyn = fleet.tree(
        n_objects=N, widths=(1,), kinds="plfua_dyn", capacities=13, refresh=30
    )
    with pytest.raises(ValueError, match="refresh"):
        StreamConfig(topo=dyn, chunk_len=G, fast=True)  # 30 % 50 != 0


def test_stream_push_contract():
    topo = _topo("lru")
    fs = FleetStream(StreamConfig(topo=topo, chunk_len=G))
    with pytest.raises(ValueError, match="shape"):
        fs.push(jnp.zeros((G + 1,), jnp.int32))
    # sticky *edge* router is fine for the engine (assignment is an input),
    # but cannot be synthesized on device — an explicit array is required
    sticky_edge = fleet.tree(
        n_objects=N, widths=(3, 1), kinds="lru", capacities=(5, 13),
        router="sticky",
    )
    fs = FleetStream(StreamConfig(topo=sticky_edge, chunk_len=G))
    with pytest.raises(ValueError, match="assignment"):
        fs.push(jnp.zeros((G,), jnp.int32))


# --------------------------------------------- level-major engine, all kinds
@pytest.mark.parametrize("scenario", ["stationary", "churn"])
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_stream_level_major_bit_identity(kind, scenario):
    """K chunks == one bounded simulate_fleet, all 9 kinds, with grouped
    telemetry + byte accounting stitched across chunk boundaries. G=50 does
    not divide plfua_dyn's refresh=30: the stream's gcd sub-chunking must
    reproduce the bounded global-time fire schedule."""
    topo = _topo(kind)
    trace = workloads.make_traces(scenario, N, 1, T, seed=3)[0]
    assignment = topo.assignment(trace)
    bounded = fleet.simulate_fleet(
        topo, jnp.asarray(trace), jnp.asarray(assignment), TEL,
        sizes=SIZES, groups=GROUPS,
    )
    cfg = StreamConfig(topo=topo, chunk_len=G, telemetry=TEL)
    fs, hits = _run_stream(cfg, trace, assignment, sizes=SIZES, groups=GROUPS)
    _assert_stream_matches(
        bounded, fs, hits, tel=True, ctx=f"{kind}/{scenario}"
    )


def test_stream_group_sum_identity():
    """The stitched grouped series sums over the group axis to the bounded
    *ungrouped* series — the group axis stays observational across chunk
    boundaries (window spill or double-bucketing at a seam would break it)."""
    topo = _topo("plfua_dyn")
    trace = workloads.make_traces("churn", N, 1, T, seed=11)[0]
    assignment = topo.assignment(trace)
    plain = fleet.simulate_fleet(
        topo, jnp.asarray(trace), jnp.asarray(assignment),
        TelemetrySpec(window=25),
    )
    cfg = StreamConfig(topo=topo, chunk_len=G, telemetry=TEL)
    fs, _ = _run_stream(cfg, trace, assignment, groups=GROUPS)
    st = fs.stats()
    for l in range(topo.n_levels):
        np.testing.assert_array_equal(
            np.asarray(st.telemetry[l]).sum(axis=2),
            np.asarray(plain["telemetry"][l]),
            err_msg=f"group-sum != ungrouped series, level {l}",
        )


# ------------------------------------------------------------- placed engine
@pytest.mark.parametrize("pl", ["lcd", "prob(0.3)", "admit"])
def test_stream_placed_bit_identity(pl):
    """Placement couples the levels per step -> the stream shares the placed
    engine's scan cell; parity covers the placement sketches' carry, the
    traced refresh schedule and the scattered telemetry."""
    topo = fleet.tree(
        n_objects=N, widths=(3, 1), kinds=("lru", "plfua_dyn"),
        capacities=(5, 13), refresh=(0, 30), placements=("lce", pl),
    )
    trace = workloads.make_traces("churn", N, 1, T, seed=5)[0]
    assignment = topo.assignment(trace)
    bounded = fleet.simulate_fleet(
        topo, jnp.asarray(trace), jnp.asarray(assignment), TEL,
        sizes=SIZES, groups=GROUPS,
    )
    cfg = StreamConfig(topo=topo, chunk_len=G, telemetry=TEL)
    fs, hits = _run_stream(cfg, trace, assignment, sizes=SIZES, groups=GROUPS)
    _assert_stream_matches(bounded, fs, hits, tel=True, ctx=f"placed {pl}")


# ------------------------------------------------------------ fast-lane path
_FAST_SPECS = {
    "lru": {}, "lfu": {}, "plfu": {"hot_size": 24}, "plfua": {"hot_size": 24},
    "plfua_dyn": {"hot_size": 24, "refresh": 2 * G}, "gdsf": {}, "tinylfu": {},
}


@pytest.mark.parametrize("kind", FAST_KINDS)
def test_stream_fast_parity(kind):
    """The compact working-set engine == the dense flat simulator, hit for
    hit and state field for state field — the candidate-prefix bound and the
    id-sorted tie-break hold across chunk boundaries (plfua_dyn's refresh =
    2 chunks exercises the boundary cond)."""
    kw = _FAST_SPECS[kind]
    spec = PolicySpec(kind=kind, n_objects=N, capacity=13, **kw)
    trace = workloads.make_traces("churn", N, 1, T, seed=7)[0]
    ref_hits, ref_state = jax_cache.simulate(spec, jnp.asarray(trace))
    topo = fleet.tree(
        n_objects=N, widths=(1,), kinds=kind, capacities=13,
        **{k: (v,) for k, v in kw.items()},
    )
    fs = FleetStream(StreamConfig(topo=topo, chunk_len=G, fast=True))
    hits = []
    for c in range(K):
        out = fs.push(jnp.asarray(trace[c * G:(c + 1) * G]))
        hits.append(np.asarray(out["hit"][0]))
    np.testing.assert_array_equal(
        np.concatenate(hits), np.asarray(ref_hits), err_msg=f"fast {kind} hits"
    )
    fstate = fs.states()[0]
    for k in ref_state:
        np.testing.assert_array_equal(
            np.asarray(ref_state[k]), np.asarray(fstate[k]),
            err_msg=f"fast {kind} state[{k}]",
        )
    st = fs.stats()
    assert st.hits == int(np.asarray(ref_hits).sum())
    assert st.requests == T
    assert int(st.tiers[0]["count"][0]) == int(ref_state["count"])


def test_stream_fast_sized_gdsf():
    """Size-aware victim scoring flows through the compact lanes (the sizes
    catalogue is gathered per lane like the sketch tables)."""
    spec = PolicySpec(kind="gdsf", n_objects=N, capacity=13)
    trace = workloads.make_traces("stationary", N, 1, T, seed=9)[0]
    ref_hits, ref_state = jax_cache.simulate(spec, jnp.asarray(trace), sizes=SIZES)
    topo = fleet.tree(n_objects=N, widths=(1,), kinds="gdsf", capacities=13)
    fs = FleetStream(StreamConfig(topo=topo, chunk_len=G, fast=True), sizes=SIZES)
    hits = []
    for c in range(K):
        out = fs.push(jnp.asarray(trace[c * G:(c + 1) * G]))
        hits.append(np.asarray(out["hit"][0]))
    np.testing.assert_array_equal(np.concatenate(hits), np.asarray(ref_hits))
    for k in ref_state:
        np.testing.assert_array_equal(
            np.asarray(ref_state[k]), np.asarray(fs.states()[0][k]),
            err_msg=f"sized gdsf state[{k}]",
        )


# --------------------------------------------------------- on-device routing
def test_stream_device_routing_hash():
    """push(assignment=None) routes on device with the id-pure hash router;
    parity against a bounded run fed the *same* device-routed assignment."""
    from repro.cdn import router

    topo = fleet.tree(
        n_objects=N, widths=(4, 1), kinds="lru", capacities=(5, 13),
    )
    trace = workloads.make_traces("stationary", N, 1, T, seed=13)[0]
    assignment = np.asarray(
        router.route_device(jnp.asarray(trace), 4, "hash", session_len=64)
    )
    bounded = fleet.simulate_fleet(
        topo, jnp.asarray(trace), jnp.asarray(assignment)
    )
    fs = FleetStream(StreamConfig(topo=topo, chunk_len=G))
    hits = []
    for c in range(K):
        out = fs.push(jnp.asarray(trace[c * G:(c + 1) * G]))  # no assignment
        hits.append(out["hit"])
    _assert_stream_matches(bounded, fs, hits, ctx="device-routed")


# ------------------------------------------- double-buffered stream_fleet
def test_stream_fleet_double_buffered_generation():
    """stream_fleet's generate-ahead loop == a bounded run over the host
    concatenation of the same on-device chunks, and the rollup carries the
    measured wall clock (req/s, J/step)."""
    from repro.workloads.device import DeviceTraceSpec, gen_stream_chunk

    n_chunks = 4
    dspec = DeviceTraceSpec("stationary", N, n_samples=1, trace_len=G, seed=17)
    topo = fleet.tree(n_objects=N, widths=(1, 1), kinds="lru", capacities=(5, 13))
    cfg = StreamConfig(topo=topo, chunk_len=G)
    st = stream_fleet(cfg, dspec, n_chunks)
    chunks = [
        np.asarray(gen_stream_chunk(dspec, jnp.int32(0), jnp.int32(c)))
        for c in range(n_chunks)
    ]
    full = jnp.asarray(np.concatenate(chunks))
    bounded = fleet.simulate_fleet(
        topo, full, jnp.zeros((n_chunks * G,), jnp.int32)
    )
    assert st.requests == n_chunks * G and st.chunks == n_chunks
    assert st.origin_misses == int(np.asarray(bounded["origin_miss"]).sum())
    for l in range(2):
        np.testing.assert_array_equal(
            np.asarray(st.tiers[l]["hits"]),
            np.asarray(bounded["tiers"][l]["hits"]),
        )
    assert st.elapsed_s is not None and st.elapsed_s > 0
    assert st.req_per_s == pytest.approx(st.requests / st.elapsed_s)
    assert st.j_per_step is not None and st.j_per_step > 0
    with pytest.raises(ValueError, match="trace_len"):
        stream_fleet(StreamConfig(topo=topo, chunk_len=G + 1), dspec, 2)
