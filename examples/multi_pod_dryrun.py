"""Lower + compile one (arch x shape) cell against the production mesh and
print its memory + roofline report. Thin wrapper over repro.launch.dryrun.

    PYTHONPATH=src python examples/multi_pod_dryrun.py --arch granite-3-2b --shape train_4k --multi-pod
"""
import os
import subprocess
import sys

if __name__ == "__main__":
    args = sys.argv[1:]
    mesh = "multi" if "--multi-pod" in args else "single"
    args = [a for a in args if a != "--multi-pod"]
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--mesh", mesh] + args
    env = dict(os.environ, PYTHONPATH="src")
    raise SystemExit(subprocess.call(cmd, env=env))
