"""Quickstart: the paper in one minute.

Runs LFU / PLFU / PLFUA (+ LRU baseline) on a Zipf(1.1) workload and prints
the paper's two metrics side by side: cache hit ratio and total management
CPU time. PLFU beats LFU on CHR; PLFUA matches/beats PLFU at lower CPU time
and a fraction of the metadata.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import registry, simulate, zipf

N_OBJECTS, RATE, TRACE = 5_000, 0.05, 50_000
case = zipf.GridCase(N_OBJECTS, RATE)

print(f"workload: Zipf(1.1), {N_OBJECTS} objects, cache {case.cache_size} "
      f"({RATE:.0%}), {TRACE} requests x3 samples\n")
print(f"{'policy':<10} {'CHR':>8} {'cpu_total_s':>12} {'metadata':>9} {'evictions':>10}")
for policy in registry.names(reference=True):
    r = simulate.run_case(policy, case, n_samples=3, trace_len=TRACE)
    print(f"{policy:<10} {r.mean_chr:>8.4f} {r.mean_cpu_s:>12.4f} "
          f"{r.mean_metadata:>9.0f} {r.mean_evictions:>10.0f}")

print("\npaper claims reproduced: PLFU > LFU (CHR), PLFUA >= PLFU with lower "
      "CPU time and ~2*rate of the metadata.")
