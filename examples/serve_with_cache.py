"""End-to-end serving driver: batched Zipf-distributed requests through the
content cache (the paper's policies in their serving home).

Generates with a small LM; repeated prompts hit the PLFUA-managed prefix
cache and skip prefill. Prints CHR, saved prefill tokens, and the energy
ledger.

    PYTHONPATH=src python examples/serve_with_cache.py --requests 60
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import energy, zipf
from repro.core.policies import POLICY_NAMES
from repro.models import build
from repro.serving import ContentCache, Request, Scheduler, SchedulerConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--objects", type=int, default=25)
    ap.add_argument("--policy", default="plfua", choices=list(POLICY_NAMES))
    ap.add_argument("--cache-objects", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config("smollm-360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab_size, 8).astype(np.int32) for i in range(args.objects)}
    trace = zipf.sample_trace(args.objects, args.requests, seed=1)

    cache = ContentCache(args.cache_objects, policy=args.policy, n_objects=args.objects)
    engine = ServeEngine(model, params, cache_len=16, content_cache=cache)
    sched = Scheduler(engine, SchedulerConfig(max_batch=8))
    for x in trace:
        sched.submit(Request(obj_id=int(x), tokens=prompts[int(x)], max_new=4))
    results = sched.drain()

    st, es = cache.stats, engine.stats
    print(f"policy={args.policy}  requests={len(results)}  CHR={st.chr:.3f}")
    print(f"prefill tokens computed={es.prefill_tokens_computed} saved={es.prefill_tokens_saved}")
    rep = energy.serving_energy(
        chr_value=st.chr, n_requests=len(results),
        n_params=7.2e9,  # price recompute at the llava-mistral-7b backbone
        prompt_len=2048, new_tokens=128, mgmt_cpu_s=st.mgmt_time_s,
    )
    for k, v in rep.row().items():
        print(f"  {k:>14}: {v:,.3f}")


if __name__ == "__main__":
    main()
