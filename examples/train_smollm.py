"""End-to-end training driver: a ~100M-param smollm-family model on the
synthetic Zipf-bigram stream, with periodic checkpoints and resume.

Reduced depth/width by default so a few hundred steps run on CPU; --full
uses the real smollm-360m config (same code path the dry-run lowers for the
production mesh).

    PYTHONPATH=src python examples/train_smollm.py --steps 200
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.models import build
from repro.train.data import DataConfig, ZipfBigramStream
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="checkpoints/smollm")
    ap.add_argument("--full", action="store_true", help="real smollm-360m dims")
    args = ap.parse_args()

    cfg = get_config("smollm-360m")
    if not args.full:
        # ~100M-class: keep the architecture, trim depth/width for CPU
        cfg = dataclasses.replace(
            cfg, n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
            d_ff=1536, vocab_size=8192, remat=False,
            param_dtype="float32", compute_dtype="float32",
        )
    model = build(cfg)
    print(f"model: {cfg.name} ({model.n_params/1e6:.1f}M params)")

    stream = ZipfBigramStream(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps))
    trainer = Trainer(
        model, tcfg,
        TrainerConfig(total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir, log_every=10),
        stream,
    )
    trainer.install_preemption_handler()
    out = trainer.run()
    print(f"\nfinal step {out['final_step']}  loss {out['final_loss']:.4f}  "
          f"stragglers flagged: {out['stragglers']}")
    print("re-run this script to resume from the latest checkpoint.")


if __name__ == "__main__":
    main()
