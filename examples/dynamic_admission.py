"""Sketch-based admission in one minute: fixing PLFUA's churn collapse.

The paper's PLFUA admits only a hot set fixed *ahead of time* — unbeatable
when ids are true popularity ranks, useless once popularity drifts. Two
sketch policies make admission adaptive at O(1) per request:

  * ``tinylfu``   — admit on a miss only if the count-min-sketch estimate of
                    the incoming object beats the eviction victim's.
  * ``plfua_dyn`` — keep PLFUA's eviction, but recompute the hot set every
                    ``refresh`` requests from sketch top-k (then halve the
                    sketch, so estimates track recent traffic).

Everything below runs in the jitted JAX tier (one device launch per policy x
scenario) and is validated decision-for-decision against the pure-Python
references in tests/test_differential.py.

    PYTHONPATH=src python examples/dynamic_admission.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import workloads
from repro.core import jax_cache

N_OBJECTS, CAP = 2_000, 60
SAMPLES, TRACE = 3, 20_000
KINDS = ("plfu", "plfua", "plfua_dyn", "tinylfu")

print(
    f"single cache, {N_OBJECTS} objects, capacity {CAP} (3%), "
    f"{SAMPLES}x{TRACE} requests; plfua_dyn refresh={jax_cache.PolicySpec(kind='plfua_dyn', n_objects=N_OBJECTS, capacity=CAP).effective_refresh}\n"
)
print(f"{'scenario':<13}" + "".join(f"{k:>11}" for k in KINDS))
chr_by = {}
for scenario in ("stationary", "churn", "flash_crowd"):
    traces = workloads.make_traces(
        scenario, N_OBJECTS, n_samples=SAMPLES, trace_len=TRACE, seed=7
    )
    row = []
    for kind in KINDS:
        spec = jax_cache.PolicySpec(kind=kind, n_objects=N_OBJECTS, capacity=CAP)
        hits = np.asarray(jax_cache.simulate_batch(spec, traces))
        chr_by[(scenario, kind)] = hits.mean()
        row.append(f"{hits.mean():>11.4f}")
    print(f"{scenario:<13}" + "".join(row))

gain = chr_by[("churn", "plfua_dyn")] - chr_by[("churn", "plfua")]
cost = chr_by[("stationary", "plfua")] - chr_by[("stationary", "plfua_dyn")]
print(
    f"\ntakeaway: on churn the sketch-refreshed hot set recovers "
    f"{gain:+.4f} CHR over the paper's frozen prefix, while giving up only "
    f"{cost:+.4f} when the prior was already right — adaptivity is ~free."
)
