"""N-tier CDN fleet in one minute: 8 edges -> 2 regionals -> 1 root, under
popularity churn, with per-tier CHR / origin-traffic / management-energy
roll-ups — then the same topology with traces synthesized *on device*.

Everything below tests/validates elsewhere against the paper's pure-Python
policies decision-for-decision (tests/test_fleet.py). Watch two things:

  * Depth pays: each extra tier absorbs part of its children's miss stream,
    so origin fetches (the expensive egress) shrink as the tree deepens,
    while management energy grows roughly with the node count — the
    CHR-vs-CPU trade-off from the paper, now at fleet scale.
  * The two sketch-admission policies (tinylfu, plfua_dyn) keep most of
    their CHR under churn while static-admission plfua collapses — same
    story as the flat cache, surviving hierarchy composition.

    PYTHONPATH=src python examples/fleet_sim.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import fleet, workloads
from repro.core import registry
from repro.workloads.device import DeviceTraceSpec

N_OBJECTS = 2_000
SAMPLES, TRACE = 2, 15_000

print(
    f"topology: 8 edges (cap 60) -> 2 regionals (cap 240) -> 1 root (cap 480),"
    f"\n{N_OBJECTS} objects, hash routing, {SAMPLES}x{TRACE} requests, churn\n"
)

traces = workloads.make_traces(
    "churn", N_OBJECTS, n_samples=SAMPLES, trace_len=TRACE, seed=0
)
print(f"{'policy':<10} {'edge CHR':>9} {'mid CHR':>8} {'root CHR':>9} "
      f"{'total':>7} {'origin':>7} {'mgmt J':>8}")
for kind in registry.names(jax=True):
    topo = fleet.tree(
        n_objects=N_OBJECTS,
        widths=(8, 2, 1),
        kinds=kind,
        capacities=(60, 240, 480),
        window=2_048 if kind == "wlfu" else 0,
    )
    out = fleet.simulate_fleet_batch(topo, traces, topo.assignment(traces))
    rep = fleet.fleet_report(topo, out)
    chrs = rep.level_chr
    print(
        f"{kind:<10} {chrs[0]:>9.4f} {chrs[1]:>8.4f} {chrs[2]:>9.4f} "
        f"{rep.total_chr:>7.4f} {rep.origin_requests:>7d} "
        f"{rep.mgmt_energy_j:>8.4f}"
    )

print("\n--- depth sweep (plfu): how many tiers is this traffic worth?")
for widths, caps in (
    ((8, 1), (60, 480)),
    ((8, 2, 1), (60, 240, 480)),
    ((8, 4, 2, 1), (60, 120, 240, 480)),
):
    topo = fleet.tree(n_objects=N_OBJECTS, widths=widths, kinds="plfu", capacities=caps)
    out = fleet.simulate_fleet_batch(topo, traces, topo.assignment(traces))
    rep = fleet.fleet_report(topo, out)
    print(
        f"  {len(widths)}-tier: total_chr={rep.total_chr:.4f} "
        f"origin={rep.origin_requests} mgmt_J={rep.mgmt_energy_j:.4f}"
    )

print("\n--- on-device generation (no host trace arrays cross the wire)")
topo = fleet.tree(
    n_objects=N_OBJECTS, widths=(8, 2, 1), kinds="plfu", capacities=(60, 240, 480)
)
dspec = DeviceTraceSpec("churn", N_OBJECTS, n_samples=SAMPLES, trace_len=TRACE, seed=0)
out, traces_dev, _ = fleet.simulate_fleet_device(topo, dspec)
rep = fleet.fleet_report(topo, out)
print(
    f"  device-generated churn: total_chr={rep.total_chr:.4f} "
    f"origin={rep.origin_requests} "
    f"(traces synthesized inside jit, shape {np.asarray(traces_dev).shape})"
)

print("\ntakeaway: tiers deepen -> origin traffic falls; the admission policy\n"
      "decides how gracefully each tier degrades when popularity moves.")
