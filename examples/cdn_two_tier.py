"""CDN fleet in one minute: 4 edge caches + a shared parent tier, under
stationary Zipf, popularity churn, and a flash crowd.

The whole two-tier hierarchy (all edges vmapped + the parent miss-stream
scan) runs as ONE jitted device launch per scenario, and is validated
elsewhere decision-for-decision against the paper's pure-Python policies
(tests/test_cdn.py). Watch two things in the output:

  * PLFUA's static hot set is great under stationary traffic and collapses
    under churn — while plfua_dyn (the same eviction with a sketch-refreshed
    hot set) and tinylfu admission follow the drift and keep most of the CHR.
  * The parent tier catches a large share of edge misses, so origin traffic
    (the expensive fetch) is a fraction of what a single cache would emit.

    PYTHONPATH=src python examples/cdn_two_tier.py
"""
import sys

sys.path.insert(0, "src")

from repro import cdn, workloads
from repro.core import registry

N_OBJECTS, N_EDGES = 2_000, 4
EDGE_CAP, PARENT_CAP = 60, 240  # 3% per edge, 12% parent
SAMPLES, TRACE = 2, 15_000

print(
    f"fleet: {N_EDGES} edges (cap {EDGE_CAP}) -> parent (cap {PARENT_CAP}), "
    f"{N_OBJECTS} objects, hash routing, {SAMPLES}x{TRACE} requests\n"
)

for scenario in ("stationary", "churn", "flash_crowd"):
    traces = workloads.make_traces(
        scenario, N_OBJECTS, n_samples=SAMPLES, trace_len=TRACE, seed=0
    )
    print(f"--- workload: {scenario}")
    print(f"{'policy':<10} {'edge CHR':>9} {'parent CHR':>11} {'total CHR':>10} "
          f"{'origin':>7} {'mgmt J':>8}")
    for kind in registry.names(jax=True):
        hspec = cdn.two_tier(
            kind, N_OBJECTS, n_edges=N_EDGES,
            edge_capacity=EDGE_CAP, parent_capacity=PARENT_CAP,
            window=2_048 if kind == "wlfu" else 0,
        )
        out = cdn.simulate_hierarchy_batch(hspec, traces, hspec.assignment(traces))
        rep = cdn.hierarchy_report(hspec, out)
        print(
            f"{kind:<10} {rep.edge_chr:>9.4f} {rep.parent_chr:>11.4f} "
            f"{rep.total_chr:>10.4f} {rep.origin_requests:>7d} "
            f"{rep.mgmt_energy_j:>8.4f}"
        )
    print()

print("takeaway: eviction policy picks the edge CHR; the admission policy's\n"
      "stationarity assumption decides how gracefully the fleet degrades when\n"
      "popularity moves.")
